"""Per-lane page tables: logical cache position -> (page, offset).

The device-visible table is a dense ``(n_slots, max_len // P)`` int32
array; row ``b`` maps lane ``b``'s logical page ``i`` to a physical page
id in the pool's plane arrays. Logical position ``t`` lives at
``(table[b, t // P], t % P)``. Cleared rows point every entry at the
scratch page (0), so a freed lane's in-flight device writes can never
corrupt a page that has been handed to another lane.

The host keeps a plain nested-list mirror and re-materializes the device
array only when rows change (``device()`` is cached between mutations);
decode ticks that allocate nothing reuse the same device array, so the
steady-state decode loop uploads no tables.
"""

from __future__ import annotations

import jax.numpy as jnp

SCRATCH_PAGE = 0


def pages_needed(n_tokens: int, page_size: int) -> int:
    """ceil(n_tokens / page_size)."""
    return -(-n_tokens // page_size)


class PageTable:
    """Host mirror + device int32 array of per-lane page mappings."""

    def __init__(self, n_slots: int, max_len: int, page_size: int):
        if max_len % page_size:
            raise ValueError(
                f"max_len {max_len} not a multiple of page_size {page_size}")
        self.n_slots = n_slots
        self.page_size = page_size
        self.n_logical = max_len // page_size
        self._rows = [[SCRATCH_PAGE] * self.n_logical
                      for _ in range(n_slots)]
        self._device = None
        self._version = 0

    # ---- mutation ---------------------------------------------------------
    def set_row(self, slot: int, pages: list[int]) -> None:
        """Map lane ``slot``'s logical pages [0, len(pages)) to ``pages``;
        the tail keeps pointing at scratch."""
        if len(pages) > self.n_logical:
            raise ValueError(
                f"{len(pages)} pages > {self.n_logical} logical slots")
        row = [SCRATCH_PAGE] * self.n_logical
        row[:len(pages)] = pages
        self._rows[slot] = row
        self._dirty()

    def set_entry(self, slot: int, logical: int, page: int) -> None:
        self._rows[slot][logical] = page
        self._dirty()

    def extend_row(self, slot: int, start_logical: int,
                   pages: list[int]) -> None:
        """Map logical pages [start_logical, start_logical+len) in place."""
        row = self._rows[slot]
        for i, pg in enumerate(pages):
            row[start_logical + i] = pg
        self._dirty()

    def clear_row(self, slot: int) -> None:
        self._rows[slot] = [SCRATCH_PAGE] * self.n_logical
        self._dirty()

    def _dirty(self) -> None:
        self._device = None
        self._version += 1

    # ---- queries ----------------------------------------------------------
    def row(self, slot: int) -> list[int]:
        return list(self._rows[slot])

    def entry(self, slot: int, logical: int) -> int:
        return self._rows[slot][logical]

    def lookup(self, slot: int, position: int) -> tuple[int, int]:
        """Logical position -> (physical page, offset within page)."""
        return (self._rows[slot][position // self.page_size],
                position % self.page_size)

    @property
    def version(self) -> int:
        """Bumped on every row mutation (see ``adopt``)."""
        return self._version

    def device(self) -> jnp.ndarray:
        """The (n_slots, n_logical) int32 device table (cached until the
        next mutation)."""
        if self._device is None:
            flat = [pg for row in self._rows for pg in row]
            self._device = jnp.asarray(flat, jnp.int32).reshape(
                self.n_slots, self.n_logical)
        return self._device

    def adopt(self, dev, version: int) -> None:
        """Re-install the device array a donated jit returned unchanged:
        donation invalidated the input buffer ``device()`` handed out, so
        the caller passes back the aliased output. Skipped when any row
        mutated since ``version`` was read (the cached array was already
        discarded and will be rebuilt from the mutated rows)."""
        if self._version == version:
            self._device = dev
