"""Hash-keyed prefix blocks with copy-on-write refcounting.

Whisper decoding starts every lane with the same ``<sot><lang><task>``
anchor tokens, and serving replays the same audio clip across lanes in
benchmarks — so the first page(s) of the self-KV cache (and the whole
cross-KV block) are byte-identical across lanes. The store indexes those
*full* prompt pages by content key and hands the same physical pages to
every matching lane, bumping pool refcounts instead of copying.

Key design points:

- Self-KV prefix pages are keyed by ``(prompt tokens, encoder digest)``:
  decoder self-K/V at layers >= 1 flows through cross-attention over the
  encoder states, so identical tokens over *different* audio produce
  different K/V — the digest is mandatory for correctness.
- Cross-KV pages are keyed by the encoder digest alone (they depend only
  on the encoder states).
- Only FULL pages are shared (``m_pages = n // P``): a partially filled
  final prompt page will be appended to by decode, which would diverge
  the shared copy. Decode's first write lands at logical page ``n // P``
  — always a private page.
- The store holds no references of its own: entries are evicted via the
  pool's ``on_free`` callback when the last holding lane drains, so
  the index can never pin pages.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

from repro.paging.allocator import PagePool


def content_digest(*parts: bytes) -> str:
    h = hashlib.sha1()
    for p in parts:
        h.update(p)
    return h.hexdigest()


@dataclasses.dataclass
class PrefixEntry:
    key: tuple
    pages: list[int]     # physical pages, in logical order


class PrefixStore:
    """Content-addressed index of shared prefix pages over one pool."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._entries: dict[tuple, PrefixEntry] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key: tuple) -> Optional[list[int]]:
        """If ``key`` is indexed, retain its pages for the caller and
        return them; otherwise record a miss and return None."""
        ent = self._entries.get(key)
        if ent is None:
            self.misses += 1
            return None
        self.hits += 1
        for pg in ent.pages:
            self.pool.retain(pg)
        return list(ent.pages)

    def publish(self, key: tuple, pages: list[int]) -> None:
        """Index ``pages`` (already owned by the publishing lane) under
        ``key``. The store takes no reference; when the first page's
        refcount hits zero the whole entry is evicted."""
        if not pages or key in self._entries:
            return
        ent = PrefixEntry(key=key, pages=list(pages))
        self._entries[key] = ent
        self.pool.set_on_free(pages[0], lambda _pg, k=key: self.evict(k))

    def evict(self, key: tuple) -> None:
        self._entries.pop(key, None)

    def max_refcount(self) -> int:
        """Highest refcount across indexed pages (capacity-point check:
        == number of lanes sharing the anchor prompt)."""
        best = 0
        for ent in self._entries.values():
            for pg in ent.pages:
                best = max(best, self.pool.refcount(pg))
        return best

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"entries": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": (self.hits / total) if total else 0.0,
                "max_refcount": self.max_refcount()}
