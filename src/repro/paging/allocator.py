"""Refcounted fixed-size page pool with a LIFO free list.

One ``PagePool`` manages the physical pages of one cache plane family
(self-KV or cross-KV). Page 0 is the reserved *scratch* page: it is
never handed out by ``alloc`` and every cleared page-table row points at
it, so in-flight device writes from frozen or just-freed lanes land in
scratch instead of corrupting a page that may already belong to another
lane. Exhaustion raises :class:`PageAllocError` — callers convert it to
an admission ``Rejection`` (``RejectCode.POOL_EXHAUSTED``); it is never
an assert, because running out of pages is a load condition, not a bug.

Shared (copy-on-write) pages are expressed through per-page refcounts:
``retain`` bumps, ``free`` drops, and the page returns to the free list
only at refcount zero. ``on_free`` callbacks let the prefix store evict
its index entry when the last lane holding a shared page drains.
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.paging.table import SCRATCH_PAGE


class PageAllocError(Exception):
    """Page pool exhausted (transient, load-dependent — not a bug)."""

    def __init__(self, pool: str, requested: int, free: int):
        self.pool = pool
        self.requested = requested
        self.free = free
        super().__init__(
            f"page pool '{pool}' exhausted: requested {requested} pages, "
            f"{free} free")


class PagePool:
    """Free-list allocator over pages ``1..n_pages-1`` (0 = scratch)."""

    def __init__(self, n_pages: int, page_size: int, *, name: str = "kv"):
        if n_pages < 2:
            raise ValueError(
                f"pool '{name}' needs >= 2 pages (1 scratch + 1 usable), "
                f"got {n_pages}")
        self.name = name
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free list: low page ids come back first, which keeps the
        # working set compact and makes tests deterministic.
        self._free = list(range(n_pages - 1, 0, -1))
        self._ref = [0] * n_pages
        self._on_free: dict[int, Callable[[int], None]] = {}

    # ---- queries ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Distinct physical pages currently allocated (excl. scratch)."""
        return (self.n_pages - 1) - len(self._free)

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def can_alloc(self, k: int) -> bool:
        return k <= len(self._free)

    # ---- alloc / retain / free --------------------------------------------
    def alloc(self, k: int) -> list[int]:
        """Allocate ``k`` pages at refcount 1; raises PageAllocError."""
        if k < 0:
            raise ValueError(f"alloc({k})")
        if k > len(self._free):
            raise PageAllocError(self.name, k, len(self._free))
        pages = [self._free.pop() for _ in range(k)]
        for pg in pages:
            self._ref[pg] = 1
        return pages

    def try_alloc(self, k: int) -> Optional[list[int]]:
        """Like ``alloc`` but returns None instead of raising."""
        if k > len(self._free):
            return None
        return self.alloc(k)

    def retain(self, page: int) -> int:
        """Add a reference to an allocated page (COW sharing)."""
        if page == SCRATCH_PAGE:
            return 0   # scratch is unowned; sharing it is a no-op
        if self._ref[page] <= 0:
            raise RuntimeError(
                f"pool '{self.name}': retain of unallocated page {page}")
        self._ref[page] += 1
        return self._ref[page]

    def free(self, page: int) -> int:
        """Drop one reference; the page returns to the free list at zero.
        Returns the remaining refcount. Double-free raises."""
        if page == SCRATCH_PAGE:
            return 0
        if self._ref[page] <= 0:
            raise RuntimeError(
                f"pool '{self.name}': double free of page {page}")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            cb = self._on_free.pop(page, None)
            if cb is not None:
                cb(page)
            self._free.append(page)
        return self._ref[page]

    def free_all(self, pages: list[int]) -> None:
        for pg in pages:
            self.free(pg)

    def set_on_free(self, page: int, cb: Callable[[int], None]) -> None:
        """Run ``cb(page)`` when ``page``'s refcount reaches zero (used by
        the prefix store to evict its index entry)."""
        self._on_free[page] = cb

    # ---- invariant checks (tests) -----------------------------------------
    def check(self) -> None:
        """Structural invariants: every page is either free (ref 0) or
        allocated (ref > 0); no duplicates in the free list."""
        if len(set(self._free)) != len(self._free):
            raise AssertionError(f"pool '{self.name}': duplicate free pages")
        free = set(self._free)
        if SCRATCH_PAGE in free:
            raise AssertionError(f"pool '{self.name}': scratch in free list")
        for pg in range(1, self.n_pages):
            if (pg in free) != (self._ref[pg] == 0):
                raise AssertionError(
                    f"pool '{self.name}': page {pg} ref={self._ref[pg]} "
                    f"free={pg in free}")
