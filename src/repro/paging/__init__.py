"""Paged KV/cross-KV cache subsystem (host-side bookkeeping).

A page pool turns the serving engine's capacity limit from
``n_slots x max_len`` *padding* into actual token bytes: each lane maps
logical cache positions to fixed-size physical pages through a per-lane
page table, pages are allocated from a refcounted free list, and
identical prompt prefixes (Whisper's ``<sot><lang><task>`` anchor)
share physical pages copy-on-write across lanes.

Everything in this package is host-side Python over plain lists and
device int32 page tables; the device-side read/write paths live in
``repro.models.attention`` (gather over page tables) and the
``paged_decode_attention`` kernel op.
"""

from repro.paging.allocator import PageAllocError, PagePool
from repro.paging.manager import LanePages, PagedKV
from repro.paging.prefix import PrefixEntry, PrefixStore
from repro.paging.table import PageTable, SCRATCH_PAGE, pages_needed

__all__ = sorted([
    "LanePages",
    "PageAllocError",
    "PagePool",
    "PageTable",
    "PagedKV",
    "PrefixEntry",
    "PrefixStore",
    "SCRATCH_PAGE",
    "pages_needed",
])
