"""Lane-level orchestration over page pools, tables, and prefix store.

``PagedKV`` is the one object the serving engine talks to. It owns:

- a self-KV pool + table (``max_len // P`` logical pages per lane) and a
  cross-KV pool + table (``enc_len // P`` logical pages per lane);
- a :class:`PrefixStore` per pool — self prefixes keyed by
  ``(prompt tokens, encoder digest)``, cross blocks by the digest alone;
- per-lane ownership records (:class:`LanePages`) so freeing a lane
  releases exactly the references it holds.

Admission allocates a lane's full extent up front —
``ceil((n + max_new) / P)`` self pages and ``ceil(enc_s / P)`` cross
pages — so decode never allocates mid-tick (no new host work on the hot
path, the one-host-sync-per-tick invariant is untouched) and a frozen
lane re-writing its last position always lands on an owned page.
Transient exhaustion raises :class:`PageAllocError` with any partial
allocation rolled back.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.paging.allocator import PageAllocError, PagePool
from repro.paging.prefix import PrefixStore
from repro.paging.table import PageTable, pages_needed


@dataclasses.dataclass
class LanePages:
    slot: int
    self_pages: list[int]          # owned refs, logical order
    cross_pages: list[int]
    self_shared: int               # leading self pages from the store
    cross_shared: int
    self_len: int = 0              # valid tokens (engine-updated)
    cross_len: int = 0             # valid encoder frames


class PagedKV:
    def __init__(self, *, n_slots: int, max_len: int, enc_len: int,
                 page_size: int, n_pages: int, n_cross_pages: int):
        self.page_size = page_size
        self.n_slots = n_slots
        self.max_len = max_len
        self.enc_len = enc_len
        self.self_pool = PagePool(n_pages, page_size, name="self")
        self.cross_pool = PagePool(n_cross_pages, page_size, name="cross")
        self.self_table = PageTable(n_slots, max_len, page_size)
        self.cross_table = PageTable(n_slots, enc_len, page_size)
        self.self_prefix = PrefixStore(self.self_pool)
        self.cross_prefix = PrefixStore(self.cross_pool)
        self.lanes: dict[int, LanePages] = {}

    # ---- capacity ---------------------------------------------------------
    def pages_for_request(self, n_tokens: int, max_new: int,
                          enc_s: int) -> tuple[int, int]:
        """Worst-case (self, cross) page demand of a request, ignoring
        prefix sharing (admission prechecks use this lower bound on
        availability conservatively... the shared-prefix discount only
        ever *reduces* the real demand)."""
        return (pages_needed(n_tokens + max_new, self.page_size),
                pages_needed(enc_s, self.page_size))

    def can_admit(self, n_tokens: int, max_new: int, enc_s: int) -> bool:
        n_self, n_cross = self.pages_for_request(n_tokens, max_new, enc_s)
        return (self.self_pool.can_alloc(n_self)
                and self.cross_pool.can_alloc(n_cross))

    def fits(self, n_tokens: int, max_new: int, enc_s: int) -> bool:
        """Could this request EVER fit (empty pool)? Permanent check."""
        n_self, n_cross = self.pages_for_request(n_tokens, max_new, enc_s)
        return (n_self <= self.self_pool.n_pages - 1
                and n_cross <= self.cross_pool.n_pages - 1)

    # ---- admission --------------------------------------------------------
    def admit_lane(self, slot: int, tokens, enc_digest: str, *,
                   max_new: int, enc_s: int) -> LanePages:
        """Allocate a lane's pages, sharing full prompt pages and cross
        blocks by content. Raises :class:`PageAllocError` (rolled back)
        on exhaustion. ``tokens``: the prompt token ids (list/sequence).
        """
        p = self.page_size
        n = len(tokens)
        total_self = pages_needed(n + max_new, p)
        m_shared = n // p      # only FULL prompt pages are shareable
        self_key = (tuple(int(t) for t in tokens[:m_shared * p]),
                    enc_digest)
        cross_key = (enc_digest, enc_s)

        self_pages: list[int] = []
        shared_n = 0
        if m_shared > 0:
            hit = self.self_prefix.lookup(self_key)
            if hit is not None:
                self_pages = hit
                shared_n = len(hit)
        cross_pages: list[int] = []
        cross_shared = 0
        n_cross = pages_needed(enc_s, p)
        hit_c = self.cross_prefix.lookup(cross_key) if enc_s else None
        if hit_c is not None:
            cross_pages = hit_c
            cross_shared = len(hit_c)

        try:
            priv = self.self_pool.alloc(total_self - shared_n)
            self_pages = self_pages + priv
            if cross_shared == 0 and n_cross:
                try:
                    cross_pages = self.cross_pool.alloc(n_cross)
                except PageAllocError:
                    self.self_pool.free_all(self_pages)
                    raise
        except PageAllocError:
            if shared_n:
                self.self_pool.free_all(self_pages[:shared_n])
            if cross_shared:
                self.cross_pool.free_all(cross_pages)
            raise

        self.self_table.set_row(slot, self_pages)
        self.cross_table.set_row(slot, cross_pages)
        lane = LanePages(slot=slot, self_pages=self_pages,
                         cross_pages=cross_pages, self_shared=shared_n,
                         cross_shared=cross_shared, self_len=n,
                         cross_len=enc_s)
        self.lanes[slot] = lane
        # publish what wasn't already indexed (the first lane with this
        # content becomes the donor; the store holds no refs of its own)
        if m_shared > 0 and shared_n == 0:
            self.self_prefix.publish(self_key, self_pages[:m_shared])
        if n_cross and cross_shared == 0:
            self.cross_prefix.publish(cross_key, cross_pages)
        return lane

    def admit_stream_lane(self, slot: int) -> LanePages:
        """Open a streaming lane: cross pages arrive via ``extend_cross``
        and self pages via ``alloc_self`` at finalize. Never shared."""
        lane = LanePages(slot=slot, self_pages=[], cross_pages=[],
                         self_shared=0, cross_shared=0)
        self.lanes[slot] = lane
        self.self_table.clear_row(slot)
        self.cross_table.clear_row(slot)
        return lane

    def alloc_self(self, slot: int, n_tokens: int, max_new: int) -> LanePages:
        """Allocate a streaming lane's self pages once the prompt length
        is known (finalize). Raises on exhaustion (nothing to roll back:
        cross pages stay owned; the caller decides the lane's fate)."""
        lane = self.lanes[slot]
        total = pages_needed(n_tokens + max_new, self.page_size)
        lane.self_pages = self.self_pool.alloc(total)
        lane.self_len = n_tokens
        self.self_table.set_row(slot, lane.self_pages)
        return lane

    def extend_cross(self, slot: int, offset: int, s_new: int):
        """Grow a streaming lane's cross block to cover frames
        [offset, offset + s_new). Returns (phys, off) int lists for those
        positions — the device extend-write's gather targets. Raises
        :class:`PageAllocError` if the pool can't supply the new pages
        (the lane keeps what it had)."""
        p = self.page_size
        lane = self.lanes[slot]
        have = len(lane.cross_pages)
        need = pages_needed(offset + s_new, p)
        if need > have:
            new = self.cross_pool.alloc(need - have)   # raises; no change
            self.cross_table.extend_row(slot, have, new)
            lane.cross_pages = lane.cross_pages + new
        lane.cross_len = offset + s_new
        phys = [lane.cross_pages[(offset + i) // p] for i in range(s_new)]
        off = [(offset + i) % p for i in range(s_new)]
        return phys, off

    # ---- copy-on-write ----------------------------------------------------
    def ensure_writable(self, slot: int, logical: int, *,
                        kind: str = "self",
                        copier: Optional[Callable[[int, int], None]] = None
                        ) -> Optional[tuple[int, int]]:
        """Guarantee lane ``slot`` exclusively owns its ``logical`` page.

        If the page is shared (refcount > 1), allocate a private page,
        call ``copier(old, new)`` to clone the content, repoint the
        lane's table entry, and drop the shared ref. Returns
        ``(old, new)`` when a clone happened, None when the lane already
        owned the page. Raises :class:`PageAllocError` when no page is
        free for the clone."""
        pool = self.self_pool if kind == "self" else self.cross_pool
        table = self.self_table if kind == "self" else self.cross_table
        lane = self.lanes[slot]
        pages = lane.self_pages if kind == "self" else lane.cross_pages
        old = pages[logical]
        if pool.refcount(old) <= 1:
            return None
        new = pool.alloc(1)[0]
        if copier is not None:
            copier(old, new)
        pages[logical] = new
        table.set_entry(slot, logical, new)
        if kind == "self" and logical < lane.self_shared:
            lane.self_shared = min(lane.self_shared, logical)
        if kind == "cross" and logical < lane.cross_shared:
            lane.cross_shared = min(lane.cross_shared, logical)
        pool.free(old)
        return old, new

    # ---- release ----------------------------------------------------------
    def free_lane(self, slot: int) -> None:
        lane = self.lanes.pop(slot, None)
        if lane is None:
            return
        self.self_pool.free_all(lane.self_pages)
        self.cross_pool.free_all(lane.cross_pages)
        self.self_table.clear_row(slot)
        self.cross_table.clear_row(slot)

    def note_len(self, slot: int, self_len: int) -> None:
        lane = self.lanes.get(slot)
        if lane is not None:
            lane.self_len = self_len

    # ---- accounting -------------------------------------------------------
    def _pool_report(self, pool: PagePool, pick) -> dict:
        p = self.page_size
        fill: dict[int, int] = {}
        for lane in self.lanes.values():
            pages, n_tok = pick(lane)
            for i, pg in enumerate(pages):
                f = max(0, min(p, n_tok - i * p))
                fill[pg] = max(fill.get(pg, 0), f)
        used = pool.used_pages
        used_tokens = sum(fill.values())
        frag = 1.0 - used_tokens / (used * p) if used else 0.0
        return {"n_pages": pool.n_pages, "page_size": p,
                "pages_in_use": used, "pages_free": pool.free_pages,
                "resident_tokens": used_tokens,
                "fragmentation": frag}

    def report(self) -> dict:
        return {
            "page_size": self.page_size,
            "self": self._pool_report(
                self.self_pool, lambda ln: (ln.self_pages, ln.self_len)),
            "cross": self._pool_report(
                self.cross_pool, lambda ln: (ln.cross_pages, ln.cross_len)),
            "prefix": {"self": self.self_prefix.stats(),
                       "cross": self.cross_prefix.stats()},
            "resident_lanes": len(self.lanes),
        }

    def check(self) -> None:
        self.self_pool.check()
        self.cross_pool.check()
