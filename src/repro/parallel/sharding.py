"""Logical-axis sharding rules (MaxText-style) with divisibility fallbacks.

Models annotate parameters and activations with *logical* axis names;
``rules_for(cfg, mesh, mode)`` binds those names to mesh axes per
architecture, falling back when a dimension does not divide the mesh axis
(pjit rejects uneven shards):

* ``heads % tp != 0``  -> context parallelism: shard q-seq (train/prefill)
  or cache kv-seq (decode) over 'model' instead of heads.
* ``kv_heads % tp != 0`` -> KV replicated over 'model' (cache seq-sharded
  for decode when also not head-sharded).
* ``experts % tp != 0``  -> per-expert d_ff tensor parallelism instead of EP.

Inside model code, ``constrain(x, *logical_axes)`` applies
``with_sharding_constraint`` when a mesh context is active and is a no-op
otherwise (CPU unit tests run without a mesh).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig

_TLS = threading.local()


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def rules_for(cfg: ArchConfig, mesh: Mesh, mode: str = "train") -> dict:
    """Map logical axis names -> mesh axis (str / tuple / None)."""
    tp = _axis_size(mesh, "model")
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in dp_axes:
        dp *= _axis_size(mesh, a)

    heads_ok = cfg.n_heads % tp == 0
    kv_ok = cfg.n_kv_heads % tp == 0
    hd_ok = cfg.head_dim % tp == 0
    ep_ok = cfg.is_moe and cfg.n_experts % tp == 0
    fsdp = mode == "train"  # shard params' embed dim over data for training
    # serve-mode KV cache when kv heads don't divide TP: shard head_dim
    # instead of the sequence — a seq-sharded cache turns every decode
    # token-write into an SPMD select-rewrite of the whole local shard
    # (§Perf cell C iteration 3); head_dim-sharded caches keep writes
    # local and add only a tiny per-step score all-reduce.
    from repro import flags as _flags
    kv_on_hd = (mode != "train" and not kv_ok and hd_ok
                and not _flags.BASELINE)

    # serve mode with heads%tp != 0: no head-TP is possible, so the big
    # attention matrices would replicate (llava: 24 GB/chip). Shard their
    # d_model dim over 'model' instead (Megatron row/col-parallel); the
    # per-step all-reduce is tiny next to weight residency.
    serve_row_tp = mode != "train" and not heads_ok

    rules: dict[str, Optional[object]] = {
        "batch": dp_axes or None,
        "embed": None,            # activation d_model stays unsharded
        "param_embed": ("data" if (fsdp and "data" in mesh.shape)
                        else "model" if serve_row_tp else None),
        "ff": "model",
        "vocab": "model",
        "heads": "model" if heads_ok else None,
        "kv_heads": "model" if kv_ok else None,
        "head_dim": "model" if kv_on_hd else None,
        "q_seq": None if heads_ok else "model",      # context parallelism
        "kv_seq": None,
        "cache_seq": ("model" if (_flags.BASELINE and mode != "train"
                                  and not kv_ok) else None),
        "experts": "model" if ep_ok else None,
        "expert_ff": None if ep_ok else "model",
        "layers": None,
        "inner": "model",         # ssm/xlstm inner expansion dim
        "ssm_heads": "model" if (cfg.ssm_state and
                                 _ssm_heads(cfg) % tp == 0) else None,
        "state": None,
        "conv": None,
        "seq": None,
    }
    return rules


def _ssm_heads(cfg: ArchConfig) -> int:
    return (cfg.ssm_expand * cfg.d_model) // cfg.ssm_head_dim


def spec_for(axes: tuple, rules: dict) -> P:
    parts = []
    used = set()
    for a in axes:
        r = rules.get(a) if a is not None else None
        # one mesh axis may bind only once per spec
        if r is None:
            parts.append(None)
            continue
        key = tuple(r) if isinstance(r, tuple) else (r,)
        if any(k in used for k in key):
            parts.append(None)
            continue
        used.update(key)
        parts.append(r)
    return P(*parts)


def tree_specs(axes_tree, rules: dict):
    return jax.tree.map(lambda axes: spec_for(axes, rules), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def tree_shardings(axes_tree, mesh: Mesh, rules: dict):
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, spec_for(axes, rules)),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def enforce_divisibility(sharding_tree, shape_tree):
    """Drop sharding on dims the mesh axis doesn't divide (pjit rejects
    uneven shards): whisper's 1500-frame cross cache, batch-1 long_500k
    decode, etc. Applied wherever concrete shapes are known."""
    def fix(sh, leaf):
        if not isinstance(sh, NamedSharding) or not hasattr(leaf, "shape"):
            return sh
        spec = tuple(sh.spec) + (None,) * (len(leaf.shape) - len(sh.spec))
        parts = []
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                parts.append(None)
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for n in names:
                size *= sh.mesh.shape[n]
            parts.append(entry if dim % size == 0 else None)
        return NamedSharding(sh.mesh, P(*parts))
    return jax.tree.map(fix, sharding_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, NamedSharding))


@contextlib.contextmanager
def logical_context(mesh: Mesh, rules: dict):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (mesh, rules)
    try:
        yield
    finally:
        _TLS.ctx = prev


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(tuple(axes), rules)
    # drop entries the dim doesn't divide (batch-1 decode, odd seq, …)
    parts = []
    for dim, entry in zip(x.shape, tuple(spec) + (None,) * x.ndim):
        if entry is None:
            parts.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        parts.append(entry if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
