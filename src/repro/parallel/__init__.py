from repro.parallel.sharding import (constrain, logical_context, rules_for,
                                     spec_for, tree_shardings, tree_specs)
