"""GPipe-style pipeline parallelism via shard_map + collective-permute.

The layer stack is cut into ``n_stages`` contiguous groups; stage *i*'s
parameters live on pipe-rank *i* (leading stacked dim sharded over the
'pipe' mesh axis). A forward pass streams ``n_micro`` microbatches through
the rotating ppermute ring: at tick *t*, rank 0 injects microbatch *t*
while rank *s* works on microbatch *t-s* — the standard GPipe schedule
with (n_stages-1) bubble ticks on each side.

Differentiable end to end (jax autodiffs through ppermute), so a PP train
step is ``jax.grad`` of ``pipeline_forward``-based loss. The tests verify
PP-forward ≡ single-device forward and that grads match.

This module exists as the optional 'pipe' axis feature (DESIGN.md §4);
the production dry-run mesh is DP×TP per the brief.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def split_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L//n_stages, ...)."""
    def re(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    return jax.tree.map(re, stacked_params)


def _stage_apply(layer_fn: Callable, stage_params, x):
    """Run this stage's layer group (scan over its layers)."""
    def body(h, lp):
        return layer_fn(lp, h), None
    x, _ = jax.lax.scan(body, x, stage_params)
    return x


def pipeline_forward(layer_fn: Callable, stage_params, mbs: jax.Array,
                     *, axis: str = "pipe",
                     n_stages: int | None = None) -> jax.Array:
    """Inside shard_map: stage_params is this rank's (1, L/S, ...) slice;
    mbs is the full (n_micro, mb, ...) input (replicated). Returns
    (n_micro, mb, ...) outputs (valid on every rank after the final
    broadcast ppermute ring completes).

    ``n_stages`` must be the static 'pipe' axis size — the ppermute ring
    and the tick count are built at trace time (``make_pipelined_fn``
    passes it from the mesh; jax<0.5 has no ``lax.axis_size``).
    """
    if n_stages is None:
        raise ValueError("pipeline_forward needs the static stage count; "
                         "pass n_stages= (make_pipelined_fn reads it from "
                         "the mesh)")
    stage = jax.lax.axis_index(axis)
    params = jax.tree.map(lambda x: x[0], stage_params)
    n_micro = mbs.shape[0]
    ticks = n_micro + n_stages - 1
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(t, carry):
        state, out = carry
        # rank 0 injects microbatch t (clamped; bubble ticks discarded)
        idx = jnp.clip(t, 0, n_micro - 1)
        inject = jax.lax.dynamic_index_in_dim(mbs, idx, 0, keepdims=False)
        x_in = jnp.where(stage == 0, inject, state)
        y = _stage_apply(layer_fn, params, x_in)
        # last stage banks microbatch t-(n_stages-1) when valid
        out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
        is_valid = (t >= n_stages - 1) & (stage == n_stages - 1)
        upd = jnp.where(is_valid, y,
                        jax.lax.dynamic_index_in_dim(out, out_idx, 0,
                                                     keepdims=False))
        out = jax.lax.dynamic_update_index_in_dim(out, upd, out_idx, 0)
        state = jax.lax.ppermute(y, axis, fwd_perm)
        return state, out

    state0 = jnp.zeros_like(mbs[0])
    out0 = jnp.zeros_like(mbs)
    _, out = jax.lax.fori_loop(0, ticks, body, (state0, out0))
    # broadcast banked outputs from the last stage to every rank
    out = jax.lax.psum(
        jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)), axis)
    return out


def make_pipelined_fn(layer_fn: Callable, mesh: Mesh, n_stages: int,
                      axis: str = "pipe") -> Callable:
    """Returns f(stacked_params, mbs) -> outputs, shard_mapped over
    ``axis``. stacked_params: (L, ...) layer stack; mbs: (n_micro, mb, ...).
    """
    def spec_params(x):
        return P(axis)   # leading stage dim sharded

    def run(stage_params, mbs):
        return pipeline_forward(layer_fn, stage_params, mbs, axis=axis,
                                n_stages=mesh.shape[axis])

    def f(stacked_params, mbs):
        staged = split_stages(stacked_params, n_stages)
        pspecs = jax.tree.map(lambda _: P(axis), staged)
        return shard_map(
            run, mesh=mesh,
            in_specs=(pspecs, P()),
            out_specs=P(),
            check_rep=False,
        )(staged, mbs)

    return f
