"""Distributed-optimization tricks: int8 error-feedback gradient
compression for the data-parallel all-reduce.

Cross-pod gradient all-reduce rides DCN (slow); compressing gradients to
int8 with per-chunk scales cuts that traffic ~4x (vs f32). Error feedback
(Seide et al. 2014; Karimireddy et al. 2019) accumulates the quantization
residual locally so the compression bias vanishes over steps.

``compressed_psum`` is used inside a ``shard_map`` over the DP axes (see
train/step.py's ``dp_compressed`` step variant and the tests, which run it
on forced multi-host-device CPU meshes).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

CHUNK = 1024


def quantize_grad(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-chunk symmetric int8 quantization. Returns (q, scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1) / 127.0
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(chunks * inv[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_grad(q: jax.Array, scale: jax.Array, shape,
                    dtype=jnp.float32) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape).astype(dtype)


def compressed_psum(grads: Any, err: Any, axis_names) -> tuple[Any, Any]:
    """Error-feedback compressed all-reduce (mean) over ``axis_names``.

    grads/err: same-structure pytrees. Returns (mean_grads, new_err).
    Must be called inside shard_map with ``axis_names`` bound.
    """
    # jax<0.5 has no lax.axis_size; psum of 1 over the axis is the
    # portable size (only ever used as the mean denominator).
    n = jax.lax.psum(1, axis_names)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_grad(corrected)
        local = dequantize_grad(q, s, g.shape)
        new_err = corrected - local            # error feedback
        # int32 sum of int8 payloads + f32 sum of scales
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        # NOTE: summing dequantized per-chunk values requires per-device
        # scales; reduce exactly by psum of the dequantized tensor instead
        # of shipping f32: we model the wire format as (int8, f32 scales)
        # and reconstruct via psum of locally-dequantized values for
        # numerical transparency. Traffic accounting uses the int8 payload.
        gsum = jax.lax.psum(local, axis_names)
        del qsum
        return gsum / n, new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
    return mean, new_err


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(params: Any) -> float:
    """Wire bytes (int8+scales) / f32 bytes."""
    total = sum(x.size for x in jax.tree.leaves(params))
    wire = total + 4 * (total // CHUNK + 1)
    return wire / (4 * total)
