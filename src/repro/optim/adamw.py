"""AdamW with global-norm clipping and warmup-cosine schedule (functional,
optimizer states inherit the parameter sharding — ZeRO-style when params
are FSDP-sharded over 'data')."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params: Any, grads: Any, state: dict,
                  cfg: AdamWConfig) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = schedule(cfg, step)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias excluded)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
