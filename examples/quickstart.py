"""Quickstart: the paper's technique end to end in 80 lines.

1. Build a Whisper-family model (the paper's target).
2. Quantize its weights to Q8_0 (paper C1/C3 — ggml block format).
3. Run the coverage / offload / energy analyses that drive the paper's
   co-design (Tables I/IV, Fig 6).
4. Run one inference through the quantized model.
5. Transcribe a synthetic waveform end to end (audio -> log-mel
   frontend -> chunked encoder -> tokens) through the serving engine,
   one-shot and streaming, with the platform energy report.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import transcribe
from repro.audio.stream import synth_waveform
from repro.configs import get_config, reduced
from repro.core.burst import offload_rate, optimal_burst
from repro.core.energy import calibrate_imax, lmm_sweep
from repro.core.footprint import coverage_cdf
from repro.core.quantize import quantize_tree
from repro.core.workload import (WHISPER_TINY, k_length_histogram,
                                 whisper_workload)
from repro.models.model import build


def main():
    # -- 1. model ----------------------------------------------------------
    cfg = reduced(get_config("whisper-tiny-en"))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    print(f"built {cfg.name} (reduced): {model.n_params():,} params")

    # -- 2. Q8_0 quantization (C1) ------------------------------------------
    q8_params = quantize_tree(params)
    n_q8 = sum(1 for l in jax.tree.leaves(q8_params)
               if getattr(l, "dtype", None) == jnp.int8)
    print(f"quantized {n_q8} weight planes to Q8_0 (1.0625 B/elem)")

    # -- 3. the paper's co-design analyses -----------------------------------
    work = whisper_workload(WHISPER_TINY, dtype="q8_0")
    cov = coverage_cdf(work, "optimized")
    print("\ncoverage CDF (optimized packing, Table I):")
    for row in cov:
        print(f"  {row.limit_bytes // 1024:4d} KB -> "
              f"{row.coverage_pct:6.2f}% of kernels fit")

    hist = k_length_histogram(work)
    print(f"\noffload rate at burst=16 (C2): {offload_rate(hist, 16):.1%}")
    best = optimal_burst(hist)
    print(f"optimal burst by the latency model: {best.burst} "
          f"(offload {best.offload:.1%})")

    w16 = whisper_workload(WHISPER_TINY, dtype="f16")
    calib = calibrate_imax(w16, work)
    pts = lmm_sweep(work, calib.model, "q8_0")
    best_pt = min(pts, key=lambda p: p.pdp_j)
    print(f"\nLMM sweep (Fig 6): PDP minimum at "
          f"{best_pt.budget_bytes // 1024} KB "
          f"({best_pt.pdp_j:.1f} J, {best_pt.latency_s:.1f} s)")

    # -- 4. inference through the Q8_0 model ---------------------------------
    frames = jnp.zeros((1, 16, cfg.d_model), jnp.bfloat16)
    tokens = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    logits, _ = model.forward(q8_params, {"enc_frames": frames,
                                          "tokens": tokens}, mode="train")
    print(f"\nQ8_0 inference OK: logits {logits.shape}, "
          f"finite={bool(jnp.isfinite(logits.astype(jnp.float32)).all())}")

    # -- 5. end-to-end ASR: audio -> tokens ----------------------------------
    wave = synth_waveform(0.4)
    one = transcribe(wave, 16_000, model=model, params=params,
                     chunk_frames=8, max_new=5, platform="imax3-28nm",
                     cache_dtype="q8_0")
    streamed = transcribe(wave, 16_000, model=model, params=params,
                          chunk_frames=8, max_new=5, stream=True,
                          engine=one.engine)
    assert streamed.tokens == one.tokens, (streamed.tokens, one.tokens)
    print(f"\ntranscribe OK: {one.n_frames} encoder frames -> "
          f"tokens {one.tokens}")
    print(f"streaming == one-shot ({len(streamed.partials)} partial "
          f"hypotheses along the way)")
    print(f"energy[{one.energy['platform']}]: "
          f"{one.energy['joules_per_audio_s']:.3e} J/audio-s "
          f"({one.energy['joules_per_token']:.3e} J/token)")


if __name__ == "__main__":
    main()
