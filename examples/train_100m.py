"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on CPU with the full production stack — synthetic sharded
data, AdamW + warmup-cosine, fault-tolerant loop, async checkpointing.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
(~100M params; a couple of minutes for the default 200 steps on CPU.)
"""

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint.store import CheckpointManager
from repro.configs import get_config
from repro.data.synthetic import SyntheticDataset
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/ckpt-train100m")
    args = ap.parse_args()

    # ~100M params: a narrow qwen3 (12L x 512d, ff 2048, 32k vocab)
    cfg = dataclasses.replace(
        get_config("qwen3-4b"), n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=4, d_head=64, d_ff=2048, vocab=32000, remat=False)
    model = build(cfg)
    print(f"training {model.n_params() / 1e6:.0f}M-param model "
          f"({cfg.n_layers}L x {cfg.d_model}d) for {args.steps} steps")

    opt_cfg = AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=0)
    ds = SyntheticDataset(cfg, seq_len=args.seq, global_batch=args.batch,
                          seed=0, n_shards=2)
    ckpt = CheckpointManager(args.ckpt, keep=2)

    def on_step(step, loss):
        if step % 20 == 0 or step in (1, 5, 10):
            print(f"  step {step:4d}  loss {loss:.4f}", flush=True)

    loop = TrainLoop(step_fn, ds, ckpt,
                     LoopConfig(total_steps=args.steps, save_every=100,
                                handle_signals=True),
                     on_step=on_step)
    state = init_train_state(model, jax.random.key(0))
    state, result = loop.run(state)

    import numpy as np
    first, last = np.mean(result.losses[:10]), np.mean(result.losses[-10:])
    print(f"done: loss {first:.3f} -> {last:.3f} over "
          f"{result.final_step} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
