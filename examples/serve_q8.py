"""Serving scenario: the paper's model, the paper's quantization.

Serves **whisper-tiny.en** (reduced dims on CPU) end-to-end through the
continuous-batching engine: audio requests carry precomputed encoder
frame embeddings, admit encodes them once and caches per-slot encoder
K/V, and decode batches lanes at different depths.

Two engines serve the identical workload:

* ``cache_dtype="bf16"``  — dense KV planes (baseline stream);
* ``cache_dtype="q8_0"``  — int8+scale planes; decode writes quantize
  the new token in place and the cache matvec routes through the
  dispatched ``q8_decode_attention`` kernel (paper C1: dequantize next
  to the dot). Cache bytes/step drop to ~0.53x of bf16.

Run:  PYTHONPATH=src python examples/serve_q8.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.kernels.api import reset_dispatch_log
from repro.models.model import build
from repro.serving.engine import AudioRequest, ServeEngine
from repro.serving.scheduler import BatchScheduler

N_REQUESTS = 10
MAX_NEW = 10


def make_requests(cfg, rng):
    reqs = []
    for uid in range(N_REQUESTS):
        n = int(rng.integers(4, 24))
        frames = rng.standard_normal(
            (int(rng.integers(8, 16)), cfg.d_model)).astype(np.float32) * 0.5
        reqs.append(AudioRequest(
            uid=uid, tokens=rng.integers(3, cfg.vocab, n).tolist(),
            max_new=MAX_NEW, eos_id=-1, enc_frames=frames))
    return reqs


PLATFORM = "imax3-28nm/32k"    # the paper's PDP-optimum target


def serve(model, params, cfg, cache_dtype):
    reset_dispatch_log()
    engine = ServeEngine(model, params, n_slots=4, max_len=64, enc_len=16,
                         cache_dtype=cache_dtype, platform=PLATFORM)
    sched = BatchScheduler(engine)
    for req in make_requests(cfg, np.random.default_rng(0)):
        sched.submit(req)
    t0 = time.monotonic()
    sched.run_until_drained()
    dt = time.monotonic() - t0
    m = sched.metrics
    rep = engine.dispatch_report()
    toks = sum(len(st.out) for st in sched.results.values())
    cache = rep["cache"]
    print(f"  [{cache_dtype}] {m.completed} reqs, {toks} tokens in "
          f"{m.ticks} ticks ({dt:.1f}s) | occupancy "
          f"{m.mean_occupancy:.2f} | TTFT {m.mean_ttft:.1f} ticks | "
          f"{toks / dt:.1f} tok/s")
    print(f"  [{cache_dtype}] KV pool {cache['kv_bytes_total'] / 1e3:.1f} kB"
          f" | cache stream {cache['bytes_per_step'] / 1e3:.1f} kB/step"
          f" | {cache['self_kv_bytes_per_token']} B/token"
          f" | {cache['traffic_ratio_vs_bf16']:.4f}x vs bf16")
    q8_routes = {k: v for k, v in rep["counters"].items()
                 if k[0] == "q8_decode_attention"}
    if q8_routes:
        print(f"  [{cache_dtype}] q8_decode_attention routing: {q8_routes}")
    er = engine.energy_report()
    print(f"  [{cache_dtype}] energy on {er['platform']}: "
          f"{er['joules_per_token']:.3e} J/token | PDP {er['pdp_j']:.3e} J"
          f" | cache stream {er['cache_energy_j']:.3e} J"
          f" ({er['power_w']:.3f} W, {er['bound']}-bound)")
    return ({uid: st.out for uid, st in sched.results.items()},
            cache["bytes_per_step"])


def main():
    cfg = reduced(get_config("whisper-tiny-en"))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))

    print(f"serving {cfg.name} (reduced) — bf16 KV cache:")
    out_bf, bytes_bf = serve(model, params, cfg, "bf16")
    print(f"serving {cfg.name} (reduced) — Q8_0 KV cache (paper variant):")
    out_q8, bytes_q8 = serve(model, params, cfg, "q8_0")

    agree = sum(a == b for a, b in zip(out_bf.values(), out_q8.values()))
    print(f"cache stream: {bytes_q8 / bytes_bf:.4f}x of bf16 "
          "(paper C1 Q8_0 LOAD saving)")
    print(f"greedy outputs identical for {agree}/{len(out_bf)} requests "
          "(Q8_0 rounding can flip near-ties; that is expected)")


if __name__ == "__main__":
    main()
