"""Serving scenario: continuous batching with Q8_0-quantized weights —
the paper's quantized-inference variant behind a production scheduler.

Compares BF16 vs Q8_0 serving of the same model: identical scheduler
behaviour, ~1.9x smaller resident weights (the paper's LOAD saving),
and reports occupancy / TTFT / tok/s.

Run:  PYTHONPATH=src python examples/serve_q8.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.quantize import Q8Tensor, quantize_tree
from repro.models.model import build
from repro.serving.engine import Request, ServeEngine
from repro.serving.scheduler import BatchScheduler


def weight_bytes(params):
    total = 0
    for leaf in jax.tree.leaves(params):
        if isinstance(leaf, (jnp.ndarray,)) or hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total


def serve(params, model, vocab, tag):
    engine = ServeEngine(model, params, n_slots=4, max_len=128)
    sched = BatchScheduler(engine)
    rng = np.random.default_rng(0)
    for uid in range(12):
        n = int(rng.integers(4, 32))
        sched.submit(Request(uid=uid,
                             tokens=rng.integers(3, vocab, n).tolist(),
                             max_new=12, eos_id=-1))
    t0 = time.monotonic()
    sched.run_until_drained()
    dt = time.monotonic() - t0
    m = sched.metrics
    toks = sum(len(st.out) for st in sched.results.values())
    print(f"  [{tag}] {m.completed} reqs, {toks} tokens in {m.ticks} ticks "
          f"({dt:.1f}s) | occupancy {m.mean_occupancy:.2f} | "
          f"TTFT {m.mean_ttft:.1f} ticks | {toks / dt:.1f} tok/s")
    return {uid: st.out for uid, st in sched.results.items()}


def main():
    cfg = reduced(get_config("qwen3-4b"))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    q8 = quantize_tree(params)

    bf16_b = weight_bytes(params)
    q8_b = sum(l.nbytes_packed if isinstance(l, Q8Tensor) else l.nbytes
               for l in jax.tree.leaves(q8)
               if hasattr(l, "nbytes") or isinstance(l, Q8Tensor))
    # Q8Tensor flattens to (q, scale) leaves; recompute properly:
    q8_b = 0
    for leaf in jax.tree.leaves(q8):
        q8_b += leaf.nbytes
    print(f"weights: f32 {bf16_b / 1e6:.1f} MB -> Q8_0 {q8_b / 1e6:.1f} MB "
          f"({bf16_b / q8_b:.2f}x smaller resident set)")

    print("serving BF16/F32 weights:")
    out_fp = serve(params, model, cfg.vocab, "f32 ")
    print("serving Q8_0 weights (paper variant):")
    out_q8 = serve(q8, model, cfg.vocab, "q8_0")

    agree = sum(a == b for a, b in
                zip(out_fp.values(), out_q8.values()))
    print(f"greedy outputs identical for {agree}/{len(out_fp)} requests "
          "(Q8_0 rounding can flip near-ties; that is expected)")


if __name__ == "__main__":
    main()
